"""Paper Figure 7 (top-k precision) + engine top-k serving latency."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, emit_row, timeit
from repro.baselines import linearize, power
from repro.core import build
from repro.graph import generators
from repro.serve import EngineConfig, QueryEngine


def run(n: int = 300, eps: float = 0.1, ks=(100, 200, 400)):
    g = generators.barabasi_albert(n, 3, seed=0, directed=False)
    S = power.all_pairs(g, c=0.6, iters=50)
    iu = np.triu_indices(g.n, 1)
    true = S[iu]
    idx = build.build_index(g, eps=eps, seed=0)
    est = idx.query_pairs(iu[0], iu[1])
    lin = linearize.build(g, R=100, seed=0)
    lin_scores = np.array([linearize.query_pair(lin, g, int(u), int(v))
                           for u, v in zip(iu[0], iu[1])])
    for k in ks:
        top_true = set(np.argsort(-true)[:k].tolist())
        p_sling = len(top_true & set(np.argsort(-est)[:k].tolist())) / k
        p_lin = len(top_true & set(np.argsort(-lin_scores)[:k].tolist())) / k
        emit(f"fig7/topk/sling/k={k}", 1e6 * p_sling, "precision x1e-6")
        emit(f"fig7/topk/linearize/k={k}", 1e6 * p_lin, "precision x1e-6")

    run_engine(n=n, eps=eps)


def run_engine(n: int = 300, eps: float = 0.1, ks=(1, 10, 50),
               n_q: int = 16, batch: int = 8):
    """Serving-path latency: fused Horner-push + top_k via QueryEngine
    vs the dense single-source + host argsort strawman."""
    g = generators.barabasi_albert(n, 3, seed=0, directed=False)
    idx = build.build_index(g, eps=eps, seed=0)
    eng = QueryEngine(idx, g, EngineConfig(
        source_batch=batch, k_buckets=tuple(ks), cache_size=0))
    eng.warmup()
    rng = np.random.default_rng(0)
    qs = rng.integers(0, g.n, n_q).astype(np.int32)
    for k in ks:
        t = timeit(lambda: eng.topk(qs, k))
        emit(f"serve/topk/engine/n={n}/k={k}", t / n_q, "fused top_k")
    # one-shot module API on its warm path: the device upload is
    # cached (core/device_state.py), so after the first call these
    # rows measure the fused push + top_k, not H2D transfer of the
    # packed index -- comparable to the engine rows above. One row per
    # push backend; identical selection (ids asserted equal), only the
    # push body changes.
    from repro.core.topk import topk_device
    k_max = max(ks)
    ids = {}
    for backend in ("lax", "pallas"):
        topk_device(idx, g, qs, k_max, backend=backend)  # prime
        t = timeit(lambda b=backend: topk_device(idx, g, qs, k_max,
                                                 backend=b))
        ids[backend] = topk_device(idx, g, qs, k_max, backend=backend)[1]
        emit_row(f"serve/topk/device_oneshot_warm/k={k_max}", n=n,
                 backend=backend, mesh=1, wall_us=t / n_q,
                 throughput=n_q / (t * 1e-6),
                 derived="cached upload" + (", interpret-mode"
                                            if backend == "pallas" else ""))
    assert np.array_equal(ids["lax"], ids["pallas"]), \
        "pallas top-k ids diverge from lax"
    # strawman: dense (B, n) back to host, argsort there
    dense = eng.single_source  # cache_size=0: always the device path
    t = timeit(lambda: np.argsort(-dense(qs), axis=1)[:, :max(ks)])
    emit(f"serve/topk/dense_argsort/n={n}/k={max(ks)}", t / n_q,
         "strawman")
