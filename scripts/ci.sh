#!/usr/bin/env bash
# CI gate: tier-1 tests + the serving-path smoke benchmark.
#
# The smoke benchmark (benchmarks/run.py --smoke) drives all three
# query types through the QueryEngine on a 500-node graph and asserts
# zero recompiles after warmup, so engine-latency regressions fail CI
# rather than landing silently. It also replays an edge-churn batch
# through update_index + swap_index (bench_update) and asserts the
# hot-swap triggers zero recompilations in the serving path, and runs
# the preprocess smoke (bench_preprocess.mesh_subprocess): 2-shard
# build equivalence plus the diagonal walk-path recompile gate. The
# mesh pytest suite below covers the sharded-build differential tests
# (tests/test_build_shard.py) at real shard counts.
#
# The serve suite runs the SLO-aware frontend's virtual-clock harness
# (tests/test_frontend.py) plus the frontend oracle-differential wall
# under a per-test deadline (the in-tree SIGALRM guard in
# tests/conftest.py -- a hung scheduler fails fast instead of wedging
# CI); the forced 2 host devices make the sharded frontend case run.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== slinglint: static invariant analyzer (AST + jaxpr + HLO) =="
# repo-wide pass run gated on the checked-in baseline: any *new*
# finding (lock-discipline, clock-seam, banned-api, jit-boundary,
# hbm-budget, collective-contract) fails CI before a test runs. The
# CLI forces 2 host devices itself so the HLO collective-contract
# pass always executes (DESIGN.md section 14).
PYTHONPATH=src python -m repro.analysis --baseline ANALYSIS_BASELINE.json

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== sharded serving suite (forced 4 host devices) =="
XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    python -m pytest -x -q -m mesh

echo "== bulk-join suite (forced 4 host devices) =="
XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    python -m pytest -x -q -m join

echo "== pallas kernel suite (interpret mode, forced 4 host devices) =="
# the fused Horner-push kernel wall (tests/test_horner_kernel.py) in
# interpret mode; the forced devices make the sharded kernel
# composition (mesh-marked cases in the pallas module) execute too
XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    python -m pytest -x -q -m pallas

echo "== serve suite: frontend virtual-clock harness (2 host devices) =="
SLING_TEST_DEADLINE=120 \
XLA_FLAGS=--xla_force_host_platform_device_count=2 \
    python -m pytest -x -q -m serve

echo "== scale smoke: 10^5-node out-of-core build under the RSS gate =="
# subprocess child with an address-space rlimit; asserts the format-v3
# streaming build + mmap serving stays out-of-core (tests/test_scale.py;
# the 10^6 variant is benchmarks/run.py --scale, not per-commit).
# Covers both builders: the prsim twin is parameterized in.
python -m pytest -x -q -m scale

echo "== prsim suite: hub-decomposed builder wall =="
# the prsim-built zoo x c oracle wall (quantized + mmap'd, served
# through the unchanged stack within the UNCHANGED planned eps,
# zero-new-compiled-shapes swap) minus the scale/serve twins already
# run above (tests/test_oracle_differential.py, DESIGN.md section 15)
python -m pytest -x -q -m "prsim and not scale and not serve"

echo "== examples smoke (API drift gate) =="
# the examples are the public face of the API: run them end to end so
# churn in e.g. EngineConfig/JoinConfig signatures fails CI instead of
# rotting in the docs
PYTHONPATH=src python examples/quickstart.py > /dev/null
PYTHONPATH=src python examples/sling_serve.py --n 400 > /dev/null
PYTHONPATH=src python examples/train_gnn_simrank.py --n 300 --steps 40 \
    > /dev/null

echo "== smoke benchmark (500-node serving guard) =="
PYTHONPATH=src python -m benchmarks.run --smoke
echo "CI OK"
